"""Deterministic sharded synthetic LM data pipeline.

Properties a 1000-node fleet needs and this provides:
- *Deterministic addressing*: token (step, global_example, position) is a
  pure hash — any host can regenerate any shard, so restarts and elastic
  resharding never replay or skip data.
- *Shard leases*: which host owns which slice of the global batch is a
  lease map, committed through the Fast Raft control plane on membership
  change (see runtime.controlplane); the pipeline just evaluates its lease.
- *Packed documents*: synthetic docs with EOS boundaries and a loss mask,
  so the loss path sees realistic packing.
- *Background prefetch*: a depth-2 thread queue hides generation latency.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


def _hash2d(a: np.ndarray, b: np.ndarray, seed: int) -> np.ndarray:
    """SplitMix64-style mixing, vectorized; returns uint64."""
    x = (a.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
         + b.astype(np.uint64) * np.uint64(0xBF58476D1CE4E5B9)
         + np.uint64(seed))
    x ^= x >> np.uint64(30)
    x *= np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(27)
    x *= np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(31)
    return x


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    eos_id: int = 0
    mean_doc_len: int = 512
    emit_embeddings: int = 0   # >0: width of precomputed frontend embeddings


class SyntheticLM:
    """Iterator of local batches for (shard_id, n_shards) of the global batch."""

    def __init__(self, cfg: DataConfig, shard_id: int = 0, n_shards: int = 1,
                 start_step: int = 0):
        assert cfg.global_batch % n_shards == 0
        self.cfg = cfg
        self.shard_id = shard_id
        self.n_shards = n_shards
        self.step = start_step
        self.local_batch = cfg.global_batch // n_shards

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        lo = self.shard_id * self.local_batch
        ex = np.arange(lo, lo + self.local_batch, dtype=np.uint64)
        pos = np.arange(cfg.seq_len + 1, dtype=np.uint64)
        gidx = ex[:, None] * np.uint64(1_000_003) + np.uint64(step)
        h = _hash2d(gidx.repeat(cfg.seq_len + 1, 1), pos[None, :].repeat(len(ex), 0),
                    cfg.seed)
        tokens = (h % np.uint64(max(cfg.vocab_size - 1, 1))).astype(np.int64) + 1
        # Insert EOS boundaries for packing (documents ~ geometric length).
        doc_break = (h % np.uint64(cfg.mean_doc_len)) == 0
        tokens = np.where(doc_break, self.cfg.eos_id, tokens)
        inp, lbl = tokens[:, :-1], tokens[:, 1:]
        mask = (lbl != cfg.eos_id).astype(np.float32)
        out = {
            "tokens": inp.astype(np.int32),
            "labels": lbl.astype(np.int32),
            "loss_mask": mask,
        }
        if cfg.emit_embeddings:
            e = _hash2d(gidx.repeat(cfg.seq_len, 1), pos[None, :-1].repeat(len(ex), 0),
                        cfg.seed + 1)
            emb = ((e % np.uint64(2048)).astype(np.float32) / 1024.0) - 1.0
            out["embeddings"] = np.repeat(
                emb[:, :, None], cfg.emit_embeddings, axis=2
            ) * (1.0 + np.arange(cfg.emit_embeddings, dtype=np.float32) / cfg.emit_embeddings)
            del out["tokens"]
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self.batch_at(self.step)
        self.step += 1
        return b


class Prefetcher:
    """Depth-N background prefetch over any batch iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.it = it
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._done = object()
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _run(self):
        try:
            for b in self.it:
                self.q.put(b)
        finally:
            self.q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        b = self.q.get()
        if b is self._done:
            raise StopIteration
        return b


@dataclasses.dataclass
class ShardLease:
    """Consensus-committed assignment of global-batch slices to hosts.

    The lease map is itself a log entry: `controlplane.assign_leases`
    proposes it through Fast Raft; hosts apply it on commit. Here it is the
    data structure + local evaluation."""

    n_shards: int
    owners: Dict[int, str]  # shard_id -> host_id

    def shards_of(self, host: str):
        return sorted(s for s, h in self.owners.items() if h == host)

    @staticmethod
    def balanced(hosts, n_shards: int) -> "ShardLease":
        owners = {s: hosts[s % len(hosts)] for s in range(n_shards)}
        return ShardLease(n_shards=n_shards, owners=owners)

    def rebalance(self, live_hosts) -> "ShardLease":
        """Reassign shards owned by dead hosts, minimally moving data."""
        live = list(live_hosts)
        owners = dict(self.owners)
        load = {h: sum(1 for o in owners.values() if o == h) for h in live}
        for s, h in sorted(owners.items()):
            if h not in live:
                tgt = min(live, key=lambda x: load[x])
                owners[s] = tgt
                load[tgt] += 1
        return ShardLease(self.n_shards, owners)
