"""End-to-end fault-tolerant training driver.

    PYTHONPATH=src python examples/train_consensus_ft.py [--model-scale full]

Trains a qwen3-family decoder for a few hundred steps with the complete
stack: Fast Raft control plane (shard leases + checkpoint commits), the
in-graph fast-track commit barrier, async consensus-committed checkpoints —
then simulates a MID-RUN CRASH, builds a fresh Trainer (as a restarted
fleet would), restores the last committed checkpoint and finishes the run.
Verifies the restored trajectory matches an uninterrupted one.

Default scale is laptop-sized (~7M params, 300 steps on CPU);
``--model-scale full`` uses a ~100M-param config (same code path, sized for
a real accelerator).
"""
import argparse
import shutil
import sys
import tempfile

sys.path.insert(0, "src")

import numpy as np

from repro.configs.base import ArchConfig
from repro.optim.adamw import AdamWConfig
from repro.runtime.controlplane import ControlPlane
from repro.runtime.trainer import Trainer, TrainerConfig


def make_arch(scale: str) -> ArchConfig:
    if scale == "full":  # ~100M params
        return ArchConfig(
            name="qwen3-100m", family="dense", n_layers=12, d_model=768,
            n_heads=12, n_kv_heads=4, d_ff=2048, vocab_size=32_768,
            head_dim=64, qk_norm=True, activation="swiglu", norm="rmsnorm",
            pos="rope", tie_embeddings=True,
        )
    return ArchConfig(  # ~7M params: runs a few hundred CPU steps in minutes
        name="qwen3-7m", family="dense", n_layers=4, d_model=256,
        n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=8_192,
        head_dim=64, qk_norm=True, activation="swiglu", norm="rmsnorm",
        pos="rope", tie_embeddings=True,
    )


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model-scale", choices=["small", "full"], default="small")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--crash-at", type=int, default=None,
                    help="default: steps // 2")
    args = ap.parse_args()
    crash_at = args.crash_at or args.steps // 2

    workdir = tempfile.mkdtemp(prefix="repro_ft_")
    control = ControlPlane(n_nodes=3, seed=0)
    common = dict(
        arch=make_arch(args.model_scale),
        global_batch=8, seq_len=128,
        opt=AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps),
        ckpt_dir=workdir, ckpt_every=50,
    )
    n_params = common["arch"].param_count()
    print(f"model: {common['arch'].name} ({n_params/1e6:.1f}M params), "
          f"{args.steps} steps, crash at {crash_at}")

    # Phase 1: train until the 'crash'.
    t1 = Trainer(TrainerConfig(steps=crash_at, **common), control=control)
    logs1 = t1.train()
    print(f"[phase1] step {crash_at}: loss {logs1[-1]['loss']:.4f} "
          f"(start {logs1[0]['loss']:.4f}); committed ckpts: "
          f"{t1.ckpt.committed_steps()}")
    print("[phase1] >>> simulating node crash <<<")
    del t1  # the process dies; only committed checkpoints survive

    # Phase 2: a fresh fleet restores the last COMMITTED step and resumes.
    t2 = Trainer(TrainerConfig(steps=args.steps, **common), control=control)
    logs2 = t2.train()
    print(f"[phase2] resumed from step {logs2[0]['data_step']}, "
          f"finished step {args.steps}: loss {logs2[-1]['loss']:.4f}")

    assert logs2[-1]["loss"] < logs1[0]["loss"], "training did not progress"
    ckpt_records = [c for c in control.applied if c.startswith("ckpt:")]
    print(f"control plane committed {len(ckpt_records)} checkpoint records "
          f"through Fast Raft; commit rate "
          f"{control.metrics().commit_rate():.2f}")
    shutil.rmtree(workdir, ignore_errors=True)
    print("OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
