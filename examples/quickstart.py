"""Quickstart: a Fast Raft cluster in 30 lines.

    PYTHONPATH=src python examples/quickstart.py

Spins up a simulated 5-node Fast Raft cluster, commits entries through the
fast track from a NON-leader proposer, compares against classic Raft, and
demonstrates surviving a leader crash.
"""
import sys

sys.path.insert(0, "src")

from repro.core.sim import Cluster

# --- Fast Raft: commit from a non-leader in 2 one-way hops.
c = Cluster(n=5, protocol="fastraft", seed=0, base_latency=5.0)
leader = c.run_until_leader()
c.run(500)
leader = c.leader()
proposer = [n for n in c.nodes if n != leader][0]
print(f"leader={leader}, proposing via {proposer} (fast track)")

eids = [c.submit(f"put k{i}=v{i}", via=proposer) for i in range(5)]
assert c.run_until_committed(eids)
print(f"5 entries committed; mean latency {c.metrics.mean_latency():.1f} sim-ms "
      f"(= 2 x 5ms hops: propose->all, votes->leader)")

# --- Classic Raft baseline: same workload costs 3 hops.
r = Cluster(n=5, protocol="raft", seed=0, base_latency=5.0)
r.run_until_leader(); r.run(500)
rl = r.leader()
rp = [n for n in r.nodes if n != rl][0]
reids = [r.submit(f"put k{i}=v{i}", via=rp) for i in range(5)]
assert r.run_until_committed(reids)
print(f"classic Raft same workload: {r.metrics.mean_latency():.1f} sim-ms "
      f"(forward->leader, append->all, acks->leader)")

# --- Fault tolerance: kill the leader, commit again.
c.crash(leader)
c.run(10_000)
new_leader = c.leader()
print(f"leader {leader} crashed; {new_leader} elected")
e = c.submit("put after=failover", via=new_leader)
assert c.run_until_committed([e])
c.check_log_consistency()
print("post-failover commit OK; committed logs consistent across nodes")
print("counters:", {k: v for k, v in c.metrics.counters.items()
                    if not k.startswith("msgs")})
