"""Batched serving with consensus-coordinated rollout.

    PYTHONPATH=src python examples/serve_batched.py

A small request queue feeds a batched prefill+decode loop (the decode_32k
serving path at laptop scale). Model-version rollout is committed through
the Fast Raft control plane before the server switches — every replica in a
fleet would flip at the same log index.
"""
import sys

sys.path.insert(0, "src")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.launch.mesh import make_host_mesh
from repro.models import zoo
from repro.runtime import spmd
from repro.runtime.controlplane import ControlPlane

MAX_LEN = 96
GEN = 24


def main() -> int:
    cfg = registry.get("qwen3-1.7b", reduced=True)
    model = zoo.build(cfg, dtype=jnp.float32)
    mesh = make_host_mesh()
    params_v1 = model.init(jax.random.PRNGKey(0))
    prefill_fn, decode_fn = spmd.build_serve_fns(model, mesh, MAX_LEN)

    control = ControlPlane(n_nodes=3, seed=1)
    assert control.rollout(f"{cfg.name}@v1")
    print("rollout v1 committed via Fast Raft")

    # A burst of 8 requests with different prompt lengths, padded & batched.
    rng = np.random.RandomState(0)
    prompts = [rng.randint(1, cfg.vocab_size, (rng.randint(8, 64),))
               for _ in range(8)]
    lens = np.array([len(p) for p in prompts])
    width = int(lens.max())
    batch_tok = np.zeros((len(prompts), width), np.int32)
    for i, p in enumerate(prompts):
        batch_tok[i, -len(p):] = p  # left-pad: aligned last positions

    t0 = time.perf_counter()
    logits, cache = prefill_fn(params_v1, {"tokens": jnp.asarray(batch_tok)})
    tokens = jnp.argmax(logits, axis=-1)[:, None]
    generated = [tokens]
    for _ in range(GEN - 1):
        logits, cache = decode_fn(params_v1, cache, {"tokens": tokens})
        tokens = jnp.argmax(logits, axis=-1)[:, None]
        generated.append(tokens)
    jax.block_until_ready(generated[-1])
    dt = time.perf_counter() - t0
    out = np.concatenate([np.asarray(t) for t in generated], axis=1)
    print(f"served {len(prompts)} requests x {GEN} new tokens "
          f"in {dt*1e3:.0f} ms ({len(prompts)*GEN/dt:.0f} tok/s on CPU)")
    for i in range(2):
        print(f"  req{i} (prompt {lens[i]} tok) -> {out[i, :10].tolist()}...")

    # Hot rollout to v2: committed BEFORE any replica switches.
    params_v2 = model.init(jax.random.PRNGKey(2))
    assert control.rollout(f"{cfg.name}@v2")
    logits2, _ = prefill_fn(params_v2, {"tokens": jnp.asarray(batch_tok)})
    print("rollout v2 committed; new weights serving "
          f"(first-logit delta {float(jnp.mean(jnp.abs(logits2 - logits))):.3f})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
