"""Hierarchical consensus failover across pods (the paper's dynamic-network
scenario at fleet scale).

    PYTHONPATH=src python examples/failover_demo.py

Two pods x 3 hosts, local consensus per pod over fast links + a global tier
of pod leaders over slow links. Demonstrates: global commit + dissemination
to every host; pod-leader crash with INVISIBLE global-membership churn (the
member is the pod, not the host); a dark pod riding through on the global
quorum; elastic data-lease rebalancing when a host is lost.
"""
import sys

sys.path.insert(0, "src")

from repro.core.hierarchy import HierarchicalCluster
from repro.data.pipeline import ShardLease

h = HierarchicalCluster(
    n_pods=3, hosts_per_pod=3, protocol="fastraft", seed=42,
    local_latency=0.5, global_latency=10.0,
)
h.bootstrap()
print(f"bootstrapped: global leader = {h.global_leader()}, "
      f"pod leaders = {{ {', '.join(f'{p}: {h.pods[p].leader()}' for p in h.pod_ids)} }}")

# 1. Global commit disseminates to every host through local logs.
eids = [h.propose_global(f"step-barrier-{i}") for i in range(3)]
assert h.run_until_globally_committed(eids)
assert h.run_until_delivered(3)
print(f"3 global entries committed "
      f"(mean latency {h.global_metrics.mean_latency():.1f} sim-ms over 10ms links) "
      f"and delivered to all pods: {h.delivered['pod0']}")

# 2. Pod-leader crash: global membership unchanged, service continues.
victim_pod = h.pod_ids[0]
dead_host = h.crash_pod_leader(victim_pod)
print(f"crashed {victim_pod}'s leader ({dead_host})")
h.run(5000)
print(f"{victim_pod} re-elected {h.pods[victim_pod].leader()}; "
      f"global members still {sorted(h.global_nodes[h.pod_ids[1]].members)}")
e = h.propose_global("after-pod-leader-crash", via_pod=h.pod_ids[1])
assert h.run_until_globally_committed([e], 60_000)
print("global tier committed through the leader handoff")

# 3. Dark pod: the global tier rides through on 2/3 quorum.
h.partition_pod(h.pod_ids[2])
e = h.propose_global("while-pod2-dark", via_pod=h.global_leader() or h.pod_ids[0])
assert h.run_until_globally_committed([e], 60_000)
h.heal_pod(h.pod_ids[2])
h.run(20_000)
h.check_consistency()
print("pod2 went dark and came back; all logs consistent")

# 4. Elastic lease rebalance after host loss (control-plane view).
lease = ShardLease.balanced([f"{p}h{i}" for p in h.pod_ids for i in range(3)], 18)
live = [x for x in lease.owners.values() if x != dead_host]
new_lease = lease.rebalance(live)
moved = sum(1 for s in lease.owners if lease.owners[s] != new_lease.owners[s])
print(f"data leases rebalanced after losing {dead_host}: "
      f"{moved}/18 shards moved (minimal movement)")
print("OK")
